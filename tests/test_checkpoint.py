"""Checkpointer: round trip, atomicity, resume, gc, elastic reshard."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, CorruptCheckpoint


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_round_trip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = tree()
    ck.save(10, t, extra={"data": {"step": 10}}, blocking=True)
    restored, extra = ck.restore(10, jax.tree.map(np.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data"]["step"] == 10


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        ck.save(s, t, blocking=True)
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]


def test_crash_mid_write_is_invisible(tmp_path):
    """A .tmp directory (simulated crash before rename) is never listed."""
    ck = Checkpointer(tmp_path)
    ck.save(5, tree(), blocking=True)
    (pathlib.Path(tmp_path) / "step_00000009.tmp").mkdir()
    assert ck.latest_step() == 5


def test_idempotent_resave(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(7, tree(), blocking=True)
    ck.save(7, tree(1), blocking=True)  # same step again: no crash
    assert ck.latest_step() == 7


def test_bit_flipped_shard_refused(tmp_path):
    """Silent media corruption must not load as weights: every shard is
    CRC32'd into meta.json at save time, and restore refuses a shard
    whose bytes no longer match."""
    ck = Checkpointer(tmp_path)
    t = tree()
    ck.save(3, t, blocking=True)
    meta = json.loads(
        (pathlib.Path(tmp_path) / "step_00000003" / "meta.json").read_text())
    assert meta["shard_crcs"], "save must record per-shard CRCs"
    shard = pathlib.Path(tmp_path) / "step_00000003" / "shard_00000.npz"
    data = bytearray(shard.read_bytes())
    data[len(data) // 2] ^= 0x01          # one flipped bit
    shard.write_bytes(bytes(data))
    with pytest.raises(CorruptCheckpoint):
        ck.restore(3, jax.tree.map(np.zeros_like, t))
    # an intact checkpoint alongside still restores
    ck.save(4, t, blocking=True)
    restored, _ = ck.restore(4, jax.tree.map(np.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_none(tmp_path):
    ck = Checkpointer(tmp_path)
    step, t, extra = ck.restore_latest(tree())
    assert step is None and t is None


def test_restore_casts_dtype(tmp_path):
    ck = Checkpointer(tmp_path)
    t = {"w": jnp.ones((3, 3), jnp.float32)}
    ck.save(1, t, blocking=True)
    like = {"w": jnp.zeros((3, 3), jnp.bfloat16)}
    restored, _ = ck.restore(1, like)
    assert restored["w"].dtype == jnp.bfloat16


def test_train_loop_resume_via_subprocess(tmp_path):
    """Full fault-tolerance integration: crash injection + auto-resume."""
    import subprocess
    import sys
    import os
    env = dict(os.environ, PYTHONPATH="src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "tiny",
            "--steps", "12", "--batch", "2", "--seq-len", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
            "--log-every", "100"]
    p1 = subprocess.run(base + ["--fail-at-step", "9"], env=env,
                        capture_output=True, text=True, cwd="/root/repo")
    assert p1.returncode == 42, p1.stderr[-1000:]
    p2 = subprocess.run(base, env=env, capture_output=True, text=True,
                        cwd="/root/repo")
    assert p2.returncode == 0, p2.stderr[-1000:]
    # the async save in flight at crash time may be lost (atomicity!);
    # resume must pick up a COMMITTED step (4 or 8), never corrupt state.
    assert "resumed from step" in p2.stdout
    assert "done" in p2.stdout
