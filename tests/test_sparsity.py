"""MPIFA_NS density allocation (App. B.2) + 2:4 baselines."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # clean container: parametrized fallback below
    HAVE_HYPOTHESIS = False

from repro.core.semistructured import (check_nm, magnitude_score, nm_mask,
                                       prune_nm, ria_score, wanda_score)
from repro.core.sparsity import (ModuleBudget, allocate_densities,
                                 owl_layer_densities, type_densities)


def budgets(n_layers=4):
    out = []
    for i in range(n_layers):
        out.append(ModuleBudget(f"b{i}/attn/q", i, "attn", 64 * 64))
        out.append(ModuleBudget(f"b{i}/mlp/up", i, "mlp", 64 * 192))
    return out


def test_type_densities_preserve_global_budget():
    bs = budgets()
    for label, d in type_densities(bs, 0.5).items():
        p_attn = sum(b.params for b in bs if b.kind == "attn")
        p_mlp = sum(b.params for b in bs if b.kind == "mlp")
        got = d["attn"] * p_attn + d["mlp"] * p_mlp
        assert got == pytest.approx(0.5 * (p_attn + p_mlp), rel=1e-9)


def test_owl_density_normalized():
    scores = [0.1, 0.5, 0.9, 0.2]
    params = [100, 100, 100, 100]
    d = owl_layer_densities(scores, params, 0.5, lam=0.08)
    assert d.shape == (4,)
    assert float((d * params).sum() / sum(params)) == pytest.approx(0.5,
                                                                    abs=1e-6)
    assert d[2] > d[0]  # more outliers -> more density


def _check_allocation_invariants(gd, nl, lam):
    bs = budgets(nl)
    rng = np.random.default_rng(nl)
    layer_d = {i: float(x) for i, x in enumerate(
        owl_layer_densities(rng.random(nl), [1] * nl, gd, lam))}
    alloc = allocate_densities(bs, gd, layer_density=layer_d,
                               type_density={"attn": gd, "mlp": gd})
    total = sum(b.params for b in bs)
    got = sum(alloc[b.name] * b.params for b in bs)
    assert got == pytest.approx(gd * total, rel=0.02)
    assert all(0.02 <= v <= 1.0 for v in alloc.values())


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(gd=st.floats(0.2, 0.9), nl=st.integers(1, 8),
           lam=st.floats(0.0, 0.1))
    def test_allocation_invariants(gd, nl, lam):
        _check_allocation_invariants(gd, nl, lam)


_ALLOC_RNG = np.random.default_rng(9)
_ALLOC_CASES = [(0.2, 1, 0.0), (0.9, 8, 0.1), (0.5, 4, 0.05)] + [
    (float(_ALLOC_RNG.uniform(0.2, 0.9)), int(_ALLOC_RNG.integers(1, 9)),
     float(_ALLOC_RNG.uniform(0.0, 0.1))) for _ in range(9)]


@pytest.mark.parametrize("gd,nl,lam", _ALLOC_CASES)
def test_allocation_invariants_sweep(gd, nl, lam):
    _check_allocation_invariants(gd, nl, lam)


def test_nm_mask_validity():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(16, 64))
    for scorer, act in [(magnitude_score, None),
                        (wanda_score, np.abs(rng.normal(size=64))),
                        (ria_score, np.abs(rng.normal(size=64)))]:
        pruned = prune_nm(w, scorer, act)
        assert check_nm(pruned, 2, 4)
        # exactly half the weights survive
        assert (pruned != 0).sum() == w.size // 2


def test_nm_mask_keeps_topk_magnitude():
    w = np.asarray([[1.0, -5.0, 0.1, 3.0]])
    m = nm_mask(magnitude_score(w))
    np.testing.assert_array_equal(m, [[False, True, False, True]])


def test_nm_handles_nondivisible_width():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(4, 10))  # 10 % 4 != 0
    pruned = prune_nm(w)
    assert check_nm(pruned)
    assert pruned.shape == w.shape
