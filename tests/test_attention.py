"""Blockwise (flash-style) attention == direct attention, all mask modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L


@pytest.fixture
def qkv():
    rng = np.random.default_rng(0)
    b, sq, sk, h, hkv, d = 2, 37, 53, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(16, 16 + sq)[None], (b, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
    return q, k, v, qpos, kpos


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 9])
@pytest.mark.parametrize("kvlen", [None, 40])
def test_blockwise_matches_direct(qkv, causal, window, kvlen, monkeypatch):
    q, k, v, qpos, kpos = qkv
    win = jnp.int32(window) if window is not None else None
    kl = jnp.full((2,), kvlen) if kvlen is not None else None
    ref = L.mha(q, k, v, causal=causal, window=win, q_positions=qpos,
                kv_positions=kpos, kv_len=kl)
    monkeypatch.setattr(L, "ATTN_DIRECT_LIMIT", 1)
    monkeypatch.setattr(L, "ATTN_Q_CHUNK", 16)
    monkeypatch.setattr(L, "ATTN_KV_CHUNK", 8)
    blk = L.mha(q, k, v, causal=causal, window=win, q_positions=qpos,
                kv_positions=kpos, kv_len=kl)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_grouping_matches_repeated_kv(qkv):
    """GQA: grouped einsum == explicitly repeating KV heads."""
    q, k, v, qpos, kpos = qkv
    out = L.mha(q, k, v, causal=True, q_positions=qpos, kv_positions=kpos)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    out_rep = L.mha(q, k_rep, v_rep, causal=True, q_positions=qpos,
                    kv_positions=kpos)
    # grouped layout interleaves differently: head h of q maps to kv h//g
    # with grouping, vs h with repeat — repeat(k, g) gives kv order
    # [0,0,1,1,...], grouped expects q heads [0g,0g+1,...] share kv0.
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_rep),
                               rtol=2e-5, atol=2e-5)


def test_sliding_window_masks_far_tokens():
    rng = np.random.default_rng(1)
    b, s, h, d = 1, 12, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v0 = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out0 = L.mha(q, k, v0, causal=True, window=jnp.int32(3))
    # changing v at position 0 must not affect outputs at positions >= 3
    v1 = v0.at[:, 0].set(99.0)
    out1 = L.mha(q, k, v1, causal=True, window=jnp.int32(3))
    np.testing.assert_allclose(np.asarray(out0[:, 3:]),
                               np.asarray(out1[:, 3:]), rtol=1e-6)
    assert float(jnp.abs(out0[:, 0] - out1[:, 0]).max()) > 1e-3


def test_decode_step_uses_kv_len():
    """Unwritten cache slots must not leak into decode attention."""
    rng = np.random.default_rng(2)
    b, h, d, L_cache = 2, 2, 8, 16
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, L_cache, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, L_cache, h, d)), jnp.float32)
    qpos = jnp.full((b, 1), 5)
    out_a = L.mha(q, k, v, causal=True, q_positions=qpos,
                  kv_len=jnp.full((b,), 6))
    # poison the tail of the cache: must be invisible
    k2 = k.at[:, 6:].set(77.0)
    v2 = v.at[:, 6:].set(-55.0)
    out_b = L.mha(q, k2, v2, causal=True, q_positions=qpos,
                  kv_len=jnp.full((b,), 6))
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               rtol=1e-6)
