"""Compress-then-serve example: the paper's deployment story.

Trains briefly, MPIFA-compresses at 55% density (the paper's
semi-structured-comparison point), then serves batched greedy decoding
with dense vs PIFA weights, reporting tokens/s, parameter bytes and
perplexity — the CPU-scale Table 7.

  PYTHONPATH=src python examples/compress_and_serve.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.data.calibration import calibration_batches
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.serve import generate
from repro.models.model import build_model, make_engine, make_train_step
from repro.optim.adamw import AdamW


def main():
    cfg = get_config("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    optim = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, cfg, optim))
    opt = optim.init(params)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8))
    print("[1] training 150 steps...")
    for i in range(150):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        loss, params, opt = step(params, opt, batch)
    print(f"    final loss {float(loss):.3f}")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                          jnp.int32)
    engine = make_engine(model)
    res_d = engine.generate(params, prompts, 32, 64)
    _, tps_leg = generate(model, params, prompts, 32, 64)
    nbytes = lambda t: sum(x.size * x.dtype.itemsize
                           for x in jax.tree.leaves(t))
    print(f"[2] dense serve: engine {res_d.tokens_per_sec:.1f} tok/s "
          f"(legacy loop {tps_leg:.1f}), {nbytes(params)/1e6:.1f} MB")

    print("[3] MPIFA compression (density 0.55, lam 0.25)...")
    t0 = time.time()
    cp = compress_transformer(
        model, params, calibration_batches(cfg.vocab_size, 8, 64),
        MpifaConfig(density=0.55))
    print(f"    compressed in {time.time()-t0:.1f}s")
    res_c = engine.generate(cp, prompts, 32, 64)
    agree = float(jnp.mean((res_c.tokens == res_d.tokens)
                           .astype(jnp.float32)))
    print(f"[4] PIFA serve: engine {res_c.tokens_per_sec:.1f} tok/s, "
          f"{nbytes(cp)/1e6:.1f} MB, token agreement {agree:.3f}")


if __name__ == "__main__":
    main()
