"""Quickstart: PIFA in 60 seconds.

1. factorize a low-rank matrix losslessly (Algorithm 1),
2. run the PIFA layer (Algorithm 2) and check it matches,
3. compress a small transformer end-to-end with MPIFA (Algorithm 3)
   and compare output quality + parameter counts.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.density import rank_for_density_pifa
from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.core.pifa import (pifa_apply, pifa_param_count, pifa_reconstruct,
                             pivoting_factorize, lowrank_param_count)
from repro.data.calibration import calibration_batches
from repro.models.model import build_model


def main():
    rng = np.random.default_rng(0)

    # --- 1. lossless factorization ------------------------------------
    m, n, r = 256, 192, 64
    w = rng.normal(size=(m, r)) @ rng.normal(size=(r, n))   # rank-r matrix
    f = pivoting_factorize(w, r)
    err = float(jnp.abs(pifa_reconstruct(f) - w).max())
    print(f"[1] PIFA reconstruction max err: {err:.2e} (lossless)")
    print(f"    params: lowrank={lowrank_param_count(m, n, r)} "
          f"pifa={pifa_param_count(m, n, r)} "
          f"(saved {r*r - r} = r^2 - r)")

    # --- 2. the PIFA layer ----------------------------------------------
    x = jnp.asarray(rng.normal(size=(8, n)), jnp.float32)
    y = pifa_apply(f, x)
    print(f"[2] layer apply err: "
          f"{float(jnp.abs(y - x @ jnp.asarray(w, jnp.float32).T).max()):.2e}")

    # --- 3. MPIFA on a model --------------------------------------------
    cfg = get_config("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calibration_batches(cfg.vocab_size, 4, 64)
    test = jax.random.randint(jax.random.PRNGKey(9), (4, 64), 0,
                              cfg.vocab_size)
    ref = model.forward(params, test)

    for density in (0.8, 0.55):
        cp = compress_transformer(model, params, calib,
                                  MpifaConfig(density=density))
        out = model.forward_unstacked(cp, test)
        rmse = float(jnp.sqrt(jnp.mean((out - ref) ** 2)))
        total = lambda t: sum(int(np.prod(l.shape))
                              for l in jax.tree.leaves(t))
        ratio = total(cp["blocks"]) / total(params["blocks"])
        print(f"[3] MPIFA density={density}: block params x{ratio:.3f}, "
              f"logit rmse {rmse:.4f}")
    print("done.")


if __name__ == "__main__":
    main()
