"""End-to-end training driver example (deliverable b).

Trains the tiny LM for a few hundred steps on the structured synthetic
stream through the production stack (pjit-able step, checkpointing,
exact-resume data pipeline), then evaluates perplexity.  ~3 minutes on
one CPU core.

  PYTHONPATH=src python examples/train_tiny_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import train


def main():
    rc = train.main([
        "--arch", "tiny",
        "--steps", "300",
        "--batch", "8",
        "--seq-len", "128",
        "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_tiny_ckpt",
        "--ckpt-every", "100",
        "--log-every", "25",
    ])
    print("train driver exited with", rc)


if __name__ == "__main__":
    main()
